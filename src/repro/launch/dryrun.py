import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell against 512 placeholder host devices, and extract the §Roofline
terms from the compiled artifact.

This is the proof that the distribution config is coherent without real
hardware: a sharding mismatch, compile-time OOM, or unsupported
collective fails the cell.  Results are cached as JSON per cell under
``--out`` so the grid can be filled incrementally (and in parallel across
processes).

Usage:
    python -m repro.launch.dryrun --arch gemma2-27b --shape train_4k --mesh multi
    python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs.registry import ARCH_IDS, all_cells, get_config, skipped_cells
from ..distributed.sharding import (batch_specs, cache_specs, param_specs,
                                    replicated, use_mesh)
from ..launch import hlo_analysis, hlo_cost
from ..launch.mesh import dp_shards, make_production_mesh
from ..models import model as M
from ..models.config import SHAPES
from ..models.io import input_specs
from ..optim.adamw import Hyper, abstract_opt_state
from ..train.steps import make_decode_step, make_prefill_step, make_train_step

MODEL_AXIS = 16
STASH_BUDGET = 2e9   # bytes of remat-stash per device before microbatching


def pick_microbatches(cfg, shape, mesh) -> int:
    dp = dp_shards(mesh)
    b_local = max(shape.global_batch // dp, 1)
    # remat stash: per-unit residual inputs
    stash = cfg.num_layers * b_local * shape.seq_len * cfg.d_model * 2
    # MoE dispatch transient: per-layer (E, cap, d + 2·ff) bf16 per device
    if cfg.n_experts:
        tok_dev = b_local * shape.seq_len
        cap = tok_dev * cfg.top_k * cfg.capacity_factor / max(cfg.n_experts, 1)
        moe_transient = (cfg.n_experts * cap
                         * (cfg.d_model + 2 * cfg.moe_d_ff) * 2)
        stash = max(stash, moe_transient * cfg.num_layers // 8)
    mb = 1
    while stash / mb > STASH_BUDGET and mb * dp < shape.global_batch:
        mb *= 2
    return mb


def lower_cell(arch: str, shape_name: str, mesh, *, multi_pod: bool):
    """Build + lower + compile one cell; returns (record, compiled)."""
    cfg = get_config(arch, pad_for_mesh=True, model_axis=MODEL_AXIS)
    shape = SHAPES[shape_name]
    specs = input_specs(cfg, shape)
    groups = dp_shards(mesh) if cfg.n_experts else 1
    params_abs = M.abstract_params(cfg)
    p_specs = param_specs(params_abs, mesh)

    t0 = time.time()
    with use_mesh(mesh):
        if shape.kind == "train":
            mb = pick_microbatches(cfg, shape, mesh)
            step = make_train_step(cfg, Hyper(), num_microbatches=mb,
                                   moe_groups=groups)
            opt_abs = abstract_opt_state(params_abs)
            o_specs = param_specs(opt_abs, mesh)
            b_specs = batch_specs(specs["batch"], mesh)
            m_specs = {k: replicated(mesh) for k in ("lr", "grad_norm", "loss")}
            jitted = jax.jit(step,
                             in_shardings=(p_specs, o_specs, b_specs),
                             out_shardings=(p_specs, o_specs, m_specs),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_abs, opt_abs, specs["batch"])
            extra = {"num_microbatches": mb}
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg, moe_groups=groups)
            b_specs = batch_specs(specs["batch"], mesh)
            jitted = jax.jit(step, in_shardings=(p_specs, b_specs))
            lowered = jitted.lower(params_abs, specs["batch"])
            extra = {}
        else:  # decode
            step = make_decode_step(cfg, moe_groups=groups)
            c_specs = cache_specs(specs["cache"], mesh,
                                  kv_shard=cfg.decode_kv_shard)
            t_specs = batch_specs(specs["tokens"], mesh)
            jitted = jax.jit(step,
                             in_shardings=(p_specs, t_specs, c_specs,
                                           replicated(mesh)),
                             donate_argnums=(2,))
            lowered = jitted.lower(params_abs, specs["tokens"],
                                   specs["cache"], specs["cache_len"])
            extra = {}
        t_lower = time.time() - t0

        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    record = analyze(compiled, cfg, shape, mesh, arch=arch,
                     shape_name=shape_name, multi_pod=multi_pod)
    record.update(extra)
    record["lower_s"] = round(t_lower, 2)
    record["compile_s"] = round(t_compile, 2)
    return record, compiled


def analyze(compiled, cfg, shape, mesh, *, arch, shape_name, multi_pod):
    chips = mesh.devices.size
    record = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "padded_dims": dict(cfg.logical),
        "kind": shape.kind,
    }

    # --- memory (proves it fits) ---------------------------------------
    try:
        ma = compiled.memory_analysis()
        record["memory"] = {
            "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "code_bytes": int(getattr(ma, "generated_code_size_in_bytes", 0)),
        }
        record["memory"]["total_bytes"] = (
            record["memory"]["argument_bytes"]
            + record["memory"]["temp_bytes"])
    except Exception as e:  # CPU backend may not implement every field
        record["memory"] = {"error": repr(e)}

    # --- raw XLA cost analysis (counts each while body ONCE — kept for
    # reference; the roofline uses the while-aware model below) ----------
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        record["cost_analysis_raw"] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            "transcendentals": float(ca.get("transcendentals", 0.0)),
        }
    except Exception as e:
        record["cost_analysis_raw"] = {"error": repr(e)}

    # --- while-aware HLO cost model (per-device) -------------------------
    hlo = compiled.as_text()
    cost = hlo_cost.analyze_hlo(hlo)
    flops = cost.flops
    bytes_accessed = cost.bytes
    record["hlo_cost"] = {
        "flops": cost.flops,
        "bytes": cost.bytes,
    }
    record["collectives"] = {
        "bytes_by_kind": {k: int(v) for k, v in cost.coll_bytes.items()},
        "count_by_kind": {k: int(v) for k, v in cost.coll_count.items()},
        "total_bytes": int(cost.total_coll_bytes),
    }
    # one-shot census (per static instruction, not trip-weighted): spot
    # remat recompute and layout churn
    stats = hlo_analysis.parse_collectives(hlo)
    record["collectives"]["largest_static"] = [
        {"kind": k, "bytes": b, "shape": s[:120]}
        for k, b, s in stats.largest[:8]]
    census = hlo_analysis.op_census(hlo)
    record["op_census_top"] = dict(
        sorted(census.items(), key=lambda kv: -kv[1])[:15])

    # --- roofline ---------------------------------------------------------
    n_params = cfg.param_count()
    n_active = cfg.param_count(active_only=True)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    factor = 6 if shape.kind == "train" else 2
    model_flops = factor * n_active * tokens
    roof = hlo_analysis.Roofline(
        flops_per_device=flops,
        hbm_bytes_per_device=bytes_accessed,
        collective_bytes_per_device=cost.total_coll_bytes,
        n_links=4)
    record["roofline"] = roof.summary()
    record["roofline"].update({
        "param_count": n_params,
        "param_count_active": n_active,
        "model_flops_global": model_flops,
        "model_flops_per_chip": model_flops / chips,
        "useful_flops_ratio": (model_flops / chips / flops) if flops else 0.0,
    })
    return record


def run_cells(cells, meshes, out_dir, force=False):
    os.makedirs(out_dir, exist_ok=True)
    results = []
    for mesh_name in meshes:
        multi = mesh_name == "multi"
        mesh = make_production_mesh(multi_pod=multi)
        for arch, shape_name in cells:
            tag = f"{'2x16x16' if multi else '16x16'}__{arch}__{shape_name}"
            path = os.path.join(out_dir, tag + ".json")
            if os.path.exists(path) and not force:
                print(f"[skip-cached] {tag}")
                continue
            print(f"[lower+compile] {tag} ...", flush=True)
            try:
                record, compiled = lower_cell(arch, shape_name, mesh,
                                              multi_pod=multi)
                del compiled
                record["status"] = "ok"
            except Exception as e:
                record = {"arch": arch, "shape": shape_name,
                          "mesh": mesh_name, "status": "error",
                          "error": repr(e),
                          "traceback": traceback.format_exc()[-2000:]}
                print(f"  ERROR: {e!r}", flush=True)
            with open(path, "w") as f:
                json.dump(record, f, indent=1)
            if record["status"] == "ok":
                r = record["roofline"]
                print(f"  ok: lower {record['lower_s']}s compile "
                      f"{record['compile_s']}s | Tc {r['t_compute_s']:.4f} "
                      f"Tm {r['t_memory_s']:.4f} Tcoll {r['t_collective_s']:.4f}"
                      f" -> {r['bottleneck']}", flush=True)
            results.append(record)
    return results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCH_IDS))
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true",
                    help="run every valid (arch, shape) cell")
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)

    assert len(jax.devices()) == 512, (
        "dry-run requires the 512 placeholder devices; do not import jax "
        "before this module sets XLA_FLAGS")

    if args.all:
        cells = all_cells()
        for arch, shape, reason in skipped_cells():
            print(f"[principled-skip] {arch} x {shape}: {reason}")
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    results = run_cells(cells, meshes, args.out, force=args.force)
    n_err = sum(r.get("status") != "ok" for r in results)
    print(f"\ndone: {len(results)} cells, {n_err} errors")
    return 1 if n_err else 0


if __name__ == "__main__":
    sys.exit(main())
