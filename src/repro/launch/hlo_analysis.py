"""Post-SPMD HLO inspection: collective bytes, op census, roofline terms.

``compiled.cost_analysis()`` reports FLOPs and bytes-accessed but nothing
about collectives, so we parse the optimized HLO text: build a table of
every instruction's result shape, then sum *operand* sizes for each
``all-gather`` / ``all-reduce`` / ``reduce-scatter`` / ``all-to-all`` /
``collective-permute`` (per the roofline spec).  Numbers are per-device —
post-partitioning HLO shapes are already the per-device shards.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

# "%name = bf16[8,128,288]{2,1,0} op-name(...)" — also matches tuple-free
# shapes like "f32[]" and named computations.
_DEF_RE = re.compile(
    r"%?([\w.\-]+)\s*=\s*(\([^)]*\)|[\w\[\],{}\s/]+?)\s+"
    r"([\w\-]+)(\.\d+)?\(([^)]*)\)")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int]
    count_by_kind: Dict[str, int]
    largest: List[Tuple[str, int, str]]   # (kind, operand bytes, result shape)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())


def parse_collectives(hlo_text: str, top_n: int = 10) -> CollectiveStats:
    # pass 1: instruction name -> result bytes
    result_bytes: Dict[str, int] = {}
    op_info: List[Tuple[str, str, str, str]] = []  # (name, shape, op, args)
    for line in hlo_text.splitlines():
        m = _DEF_RE.search(line)
        if not m:
            continue
        name, shape_str, op = m.group(1), m.group(2), m.group(3)
        result_bytes[name] = _shape_bytes(shape_str)
        base_op = op.rstrip("0123456789.")
        if base_op.endswith("-start"):
            base_op = base_op[:-len("-start")]
        if base_op in COLLECTIVE_KINDS:
            op_info.append((name, shape_str, base_op, m.group(5)))

    bytes_by_kind = {k: 0 for k in COLLECTIVE_KINDS}
    count_by_kind = {k: 0 for k in COLLECTIVE_KINDS}
    largest: List[Tuple[str, int, str]] = []
    arg_re = re.compile(r"%?([\w.\-]+)")
    for name, shape_str, kind, args in op_info:
        operand_bytes = 0
        for token in args.split(","):
            token = token.strip()
            am = arg_re.match(token)
            if am and am.group(1) in result_bytes:
                operand_bytes += result_bytes[am.group(1)]
        if operand_bytes == 0:
            # operand not found (e.g. inlined constant) — fall back to the
            # result size, which upper-bounds the operand for reduce-style
            # ops and equals output for all-reduce.
            operand_bytes = _shape_bytes(shape_str)
        bytes_by_kind[kind] += operand_bytes
        count_by_kind[kind] += 1
        largest.append((kind, operand_bytes, shape_str.strip()))
    largest.sort(key=lambda t: -t[1])
    return CollectiveStats(bytes_by_kind=bytes_by_kind,
                           count_by_kind=count_by_kind,
                           largest=largest[:top_n])


def op_census(hlo_text: str) -> Dict[str, int]:
    """Instruction-kind histogram — used by §Perf to spot remat recompute
    (duplicate fusions) and layout churn (transpose/reshape counts)."""
    census: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.search(line)
        if not m:
            continue
        op = m.group(3).rstrip("0123456789.")
        census[op] = census.get(op, 0) + 1
    return census


# ---------------------------------------------------------------------------
# roofline terms (TPU v5e)
# ---------------------------------------------------------------------------

PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW_PER_LINK = 50e9          # bytes/s per link (~)


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    hbm_bytes_per_device: float
    collective_bytes_per_device: float
    n_links: int

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_per_device / (self.n_links * ICI_BW_PER_LINK)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def summary(self) -> Dict[str, float]:
        return {
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "roofline_fraction": (self.t_compute / self.t_bound
                                  if self.t_bound > 0 else 0.0),
        }
