import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Byte/FLOP profiler for one dry-run cell: recursive while-weighted
breakdown of the biggest contributors (the §Perf 'profile' on a CPU-only
container — reasoned from lowered IR, not wall-clock).

    python -m repro.launch.profile_cell --arch command-r-35b --shape train_4k
"""

import argparse
import sys

import jax

from ..launch import hlo_cost
from ..launch.mesh import make_production_mesh


def drill(mc: "hlo_cost.ModuleCost", comp_name: str, mult: float, depth: int,
          min_bytes: float, max_depth: int):
    comp = mc.comps[comp_name]
    shapes = comp.instr_shapes()
    rows = []
    for i in comp.instrs:
        b = f = 0.0
        if i.op == "while":
            body = hlo_cost._ATTR_BODY_RE.search(i.tail)
            m = hlo_cost._TRIP_CFG_RE.search(i.tail)
            trips = float(m.group(1)) if m else 1.0
            c = mc.comp_cost(body.group(1))
            b, f = trips * c.bytes, trips * c.flops
        else:
            # reuse the walker's per-instruction rules via a one-op pass
            tmp = hlo_cost.Cost()
            mc_shapes = shapes

            def operand_bytes(ins):
                return sum(hlo_cost._shape_bytes(mc_shapes.get(o, ""))
                           for o in ins.operands)
            if i.op == "fusion":
                mm = hlo_cost._ATTR_CALLS_RE.search(i.tail)
                if mm:
                    f = mc._fused_flops(mm.group(1))
                if "dynamic-update-slice" in i.name:
                    ob = [hlo_cost._shape_bytes(mc_shapes.get(o, ""))
                          for o in i.operands]
                    b = 2 * (sum(ob) - max(ob)) if ob else 0
                elif "dynamic-slice" in i.name and "dot" not in i.name:
                    b = 2 * hlo_cost._shape_bytes(i.shape_str)
                else:
                    util = mc._fusion_param_util(mm.group(1)) if mm else {}
                    b = sum(util.get(k, hlo_cost._shape_bytes(
                        mc_shapes.get(o, ""))) for k, o in
                        enumerate(i.operands)) + hlo_cost._shape_bytes(i.shape_str)
            elif i.op in hlo_cost.COLLECTIVE_KINDS:
                b = operand_bytes(i) or hlo_cost._shape_bytes(i.shape_str)
            elif i.op == "dot":
                f = hlo_cost._dot_flops(i, mc_shapes)
                b = operand_bytes(i) + hlo_cost._shape_bytes(i.shape_str)
            elif i.op in hlo_cost._SKIP_BYTES_OPS:
                pass
            elif i.op in ("dynamic-slice", "gather"):
                b = 2 * hlo_cost._shape_bytes(i.shape_str)
            elif i.op == "dynamic-update-slice":
                b = (2 * hlo_cost._shape_bytes(
                    mc_shapes.get(i.operands[1], ""))
                    if len(i.operands) > 1 else 0)
            else:
                b = operand_bytes(i) + hlo_cost._shape_bytes(i.shape_str)
                f = hlo_cost._shape_elems(i.shape_str)
        rows.append((i, b * mult, f * mult))
    rows.sort(key=lambda r: -r[1])
    for i, b, f in rows[:10]:
        if b < min_bytes:
            continue
        import re
        meta = re.search(r'op_name="([^"]{0,90})"', i.tail)
        print("  " * depth + f"{i.op}:{i.name} -> {b:.2e} B {f:.2e} F  "
              f"[{i.shape_str[:48]}] {meta.group(1)[-60:] if meta else ''}")
        if i.op == "while" and depth < max_depth:
            body = hlo_cost._ATTR_BODY_RE.search(i.tail).group(1)
            m = hlo_cost._TRIP_CFG_RE.search(i.tail)
            trips = float(m.group(1)) if m else 1.0
            drill(mc, body, mult * trips, depth + 1, min_bytes, max_depth)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi", action="store_true")
    ap.add_argument("--min-gb", type=float, default=0.2)
    ap.add_argument("--depth", type=int, default=3)
    ap.add_argument("--save-hlo", default=None)
    args = ap.parse_args(argv)

    from ..launch import dryrun
    mesh = make_production_mesh(multi_pod=args.multi)
    rec, compiled = dryrun.lower_cell(args.arch, args.shape, mesh,
                                      multi_pod=args.multi)
    txt = compiled.as_text()
    if args.save_hlo:
        open(args.save_hlo, "w").write(txt)
    mc = hlo_cost.ModuleCost(txt)
    cost = mc.entry_cost()
    print(f"{args.arch} x {args.shape}: flops {cost.flops:.3e} "
          f"bytes {cost.bytes:.3e} coll {cost.total_coll_bytes:.3e}")
    drill(mc, mc.entry, 1.0, 0, args.min_gb * 1e9, args.depth)
    return 0


if __name__ == "__main__":
    sys.exit(main())
