"""Streaming ingest: serve similarity search while the corpus changes.

    PYTHONPATH=src python examples/streaming_ingest.py

The static bST (``build_bst``) consumes the whole database up front; the
dynamic segmented index (``repro.core.segments``, DESIGN.md §4) keeps a
mutable delta buffer in front of immutable bST segments so inserts and
deletes land without ever blocking search.  This example

  1. streams 10k sketches in through ``insert`` (auto-flushing sealed
     segments along the way),
  2. queries mid-stream (delta buffer + segments answer together),
  3. deletes a slice and triggers a size-tiered ``merge`` + ``compact``,
  4. verifies recall the strong way: after at least one merge, the
     segmented ``topk_batch`` must return **exactly** the same
     (distance, id) pairs as a fresh static bST built from the surviving
     sketches.
"""

import numpy as np

from repro.core import SegmentedIndex, build_bst, topk_batch


def main():
    rng = np.random.default_rng(0)
    n, L, b, k = 10_000, 16, 2, 10
    db = rng.integers(0, 1 << b, size=(n, L), dtype=np.uint8)
    queries = np.concatenate([
        db[rng.integers(0, n, 4)],
        rng.integers(0, 1 << b, size=(2, L), dtype=np.uint8)])

    # 1. stream the corpus in (chunks of 500; delta seals every 1800 —
    #    chosen so the mid-stream query below sees a non-empty delta)
    idx = SegmentedIndex(L, b, delta_cap=1800)
    inserted = np.zeros((0,), np.int64)
    for lo in range(0, n // 2, 500):
        inserted = np.concatenate([inserted, idx.insert(db[lo:lo + 500])])

    # 2. query mid-stream: sealed segments + the live delta buffer
    st = idx.stats()
    assert st["delta_rows"] > 0  # the delta buffer really answers queries
    mid = idx.topk_batch(queries, k)
    print(f"mid-stream: {st['n_live']} live ids across "
          f"{len(st['segments'])} segments + {st['delta_rows']} delta rows; "
          f"top-1 dists {np.asarray(mid.dists)[:, 0].tolist()} (tau*={mid.tau})")

    # 3. keep streaming, delete 1500 ids, force a merge + compact
    for lo in range(n // 2, n, 500):
        inserted = np.concatenate([inserted, idx.insert(db[lo:lo + 500])])
    victims = inserted[rng.choice(n, 1500, replace=False)]
    removed = idx.delete(victims)
    idx.flush()
    idx.maybe_merge()
    if idx.counters["merges"] == 0:   # tiny tiers can miss: force one
        idx.merge()
    idx.compact(min_dead_frac=0.1)
    st = idx.stats()
    print(f"after stream: removed {removed}, merges={st['merges']}, "
          f"compactions={st['compactions']}, segments="
          f"{st['segments']}, space={st['space_bits'] / 8 / 1024:.1f} KiB")
    assert st["merges"] >= 1

    # 4. recall check: bit-identical to a fresh static build on survivors
    surv = np.ones(n, bool)
    surv[victims] = False
    surv_ids = np.flatnonzero(surv)
    static = topk_batch(build_bst(db[surv], b), queries, k)
    mapped = np.where(np.asarray(static.ids) >= 0,
                      surv_ids[np.maximum(np.asarray(static.ids), 0)], -1)
    dyn = idx.topk_batch(queries, k)
    np.testing.assert_array_equal(np.asarray(dyn.dists),
                                  np.asarray(static.dists))
    np.testing.assert_array_equal(np.asarray(dyn.ids), mapped)
    print(f"recall check: segmented top-{k} == static rebuild on "
          f"{surv.sum()} survivors (exact ids AND distances) — OK")


if __name__ == "__main__":
    main()
