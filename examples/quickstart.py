"""Quickstart: build a bST over b-bit sketches and run similarity search.

    PYTHONPATH=src python examples/quickstart.py

Covers the paper end-to-end in miniature: sketch vectorial data with
b-bit minhash, build the succinct trie, search at several thresholds,
compare against brute force, and print the space accounting (Table III's
quantities)."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.bst import build_bst, build_louds
from repro.core.hamming import hamming_pairwise_naive
from repro.core.search import make_batch_searcher, topk_batch
from repro.core.sketch import bbit_minhash, jaccard


def main():
    rng = np.random.default_rng(0)

    # 1. vectorial data: 20k binary fingerprints over 5k dimensions
    n, dim, L, b = 20_000, 5_000, 16, 2
    items = rng.integers(0, dim, size=(n, 40)).astype(np.int32)
    mask = np.ones_like(items, dtype=bool)

    # 2. similarity-preserving hashing -> b-bit sketches (paper §I)
    key = jax.random.PRNGKey(42)
    sketches = np.asarray(bbit_minhash(key, jnp.asarray(items),
                                       jnp.asarray(mask), L=L, b=b))
    print(f"sketched {n} fingerprints -> {L}-dim {b}-bit sketches")

    # 3. build the succinct trie (paper §V)
    index = build_bst(sketches, b)
    louds = build_louds(sketches, b)
    print(f"bST layers: dense<= {index.lm}, collapse at {index.ls}, "
          f"kinds={index.kinds}")
    print(f"space: bST {index.model_bits() / 8 / 1024:.1f} KiB vs "
          f"LOUDS {louds.model_bits() / 8 / 1024:.1f} KiB "
          f"({louds.model_bits() / index.model_bits():.2f}x smaller)")

    # 4. search (paper Alg. 1, level-synchronous form)
    queries = jnp.asarray(sketches[:8])
    for tau in (1, 2, 3):
        res = make_batch_searcher(index, tau)(queries)
        hits = np.asarray(res.mask).sum(axis=1)
        print(f"tau={tau}: solutions per query {hits.tolist()} "
              f"(traversed ~{int(np.asarray(res.traversed).mean())} nodes "
              f"of {index.t[-1]} leaves)")

    # 5. top-k nearest neighbors (τ-escalation ladder + exact distances)
    nn = topk_batch(index, queries, k=3)
    print(f"top-3 of query 0: ids={np.asarray(nn.ids[0])} "
          f"dists={np.asarray(nn.dists[0])} (tau*={nn.tau})")

    # 6. verify against brute force
    dists = np.asarray(hamming_pairwise_naive(queries, jnp.asarray(sketches)))
    want = (dists <= 2).sum(axis=1)
    got = np.asarray(make_batch_searcher(index, 2)(queries).mask).sum(axis=1)
    assert (want == got).all(), (want, got)
    np.testing.assert_array_equal(
        np.asarray(nn.dists), np.sort(dists, axis=1)[:, :3])
    print("brute-force check: OK")


if __name__ == "__main__":
    main()
