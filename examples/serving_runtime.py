"""Serving-runtime walkthrough: two tenants, one micro-batching
scheduler (DESIGN.md §5).

Registers two collections with different geometries and merge policies,
starts the threaded scheduler, pushes a mixed request stream (inserts,
deletes, individually submitted top-k lookups that the scheduler
coalesces into power-of-two shape buckets), demonstrates overload
rejection on a tiny queue, and prints the ``/stats`` dump.

Run: ``PYTHONPATH=src python examples/serving_runtime.py``
"""

import numpy as np

from repro.serving import (CollectionConfig, OverloadError, Scheduler,
                           SchedulerConfig)

rng = np.random.default_rng(0)

sched = Scheduler(config=SchedulerConfig(max_batch=16, max_queue=256,
                                         max_wait_ms=2.0))
# tenant isolation: each collection has its own geometry, merge policy,
# queue, and worker — "products" compacts eagerly after deletes
sched.create_collection("docs", CollectionConfig(L=32, b=4, delta_cap=512))
sched.create_collection("products", CollectionConfig(
    L=16, b=2, delta_cap=256, compact_dead_frac=0.3))
sched.start()

# -- ingest two corpora through the write surface ---------------------------
docs = rng.integers(0, 16, size=(2000, 32), dtype=np.uint8)
prods = rng.integers(0, 4, size=(1000, 16), dtype=np.uint8)
doc_ids = sched.submit_insert("docs", docs).result()
prod_ids = sched.submit_insert("products", prods).result()
print(f"ingested {len(doc_ids)} docs + {len(prod_ids)} products")

# -- a burst of single-query lookups: the scheduler coalesces them ----------
futs = [sched.submit_topk("docs", docs[i], k=5) for i in range(40)]
answers = [f.result() for f in futs]
assert all(int(a.ids[0]) == i for i, a in enumerate(answers))  # self is NN
print(f"40 individually submitted lookups -> "
      f"batch-fill {sched.metrics.batch_fill_ratio():.2f} "
      f"(1.0 = every dispatch filled its power-of-two bucket)")

# -- writes interleave without ever recompiling a searcher ------------------
removed = sched.submit_delete("products", prod_ids[:300]).result()
nn = sched.submit_topk("products", prods[0], k=3).result()
assert int(nn.ids[0]) != 0                # id 0 was tombstoned
print(f"deleted {removed} products; post-delete NN of products[0]: "
      f"{nn.ids.tolist()} (id 0 gone, no re-jit)")

# -- admission control: a full queue rejects instead of queueing forever ----
tiny = Scheduler(config=SchedulerConfig(max_queue=4))
tiny.create_collection("t", CollectionConfig(L=8, b=2))
rejected = 0
for i in range(10):                       # never pumped -> queue fills
    try:
        tiny.submit_search("t", np.zeros(8, np.uint8), tau=1)
    except OverloadError:
        rejected += 1
print(f"overload demo: {rejected}/10 requests explicitly rejected")

sched.stop()
print("\n--- /stats ---")
print(sched.render_stats())
