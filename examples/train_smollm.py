"""End-to-end driver: train a ~100M-class model (smollm-135m family) for a
few hundred steps on synthetic data with bST near-duplicate filtering,
checkpoint/restart, and loss-curve reporting.

    PYTHONPATH=src python examples/train_smollm.py             # full (slow on CPU)
    PYTHONPATH=src python examples/train_smollm.py --smoke     # reduced config

This is a thin veneer over ``repro.launch.train`` — the same launcher the
cluster deployment uses."""

import argparse
import sys

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()
    steps = args.steps or (60 if args.smoke else 300)
    argv = ["--arch", "smollm-135m", "--steps", str(steps),
            "--batch", "8", "--seq", "128" if args.smoke else "512",
            "--dedup", "--ckpt-dir", "/tmp/repro_smollm_ckpt",
            "--ckpt-every", "50", "--log-every", "10"]
    if args.smoke:
        argv.append("--smoke")
    return train_main(argv)


if __name__ == "__main__":
    sys.exit(main())
