"""Serving example: batched autoregressive generation + the paper's
sketch-retrieval plane (0-bit CWS of request states -> bST lookup), now
returning the top-k nearest documents per request with exact distances.

    PYTHONPATH=src python examples/retrieval_serve.py
"""

import sys

from repro.launch.serve import main as serve_main


def main():
    return serve_main(["--arch", "smollm-135m", "--smoke", "--batch", "4",
                       "--prompt-len", "24", "--gen-len", "12",
                       "--retrieval", "--index-size", "2048", "--tau", "3",
                       "--topk", "3"])


if __name__ == "__main__":
    sys.exit(main())
