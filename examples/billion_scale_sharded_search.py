"""The paper's technique at pod scale: shard a sketch database across
every local device, search with ONE SPMD program (common layer plan,
padded per-shard tries, dynamic sizes), merge results — and project the
space accounting to the paper's billion-sketch SIFT setting.

    PYTHONPATH=src python examples/billion_scale_sharded_search.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import PAPER_DATASETS
from repro.core.bst import build_bst
from repro.core.distributed_search import (build_sharded_bst, gather_ids,
                                           gather_topk, make_sharded_searcher)
from repro.core.hamming import hamming_pairwise_naive


def main():
    cfg = PAPER_DATASETS["sift"]          # L=32, b=4 (1B sketches in paper)
    n, n_shards, tau, m = 200_000, 8, 2, 16
    rng = np.random.default_rng(0)
    db = rng.integers(0, 1 << cfg.b, size=(n, cfg.L), dtype=np.uint8)
    queries = jnp.asarray(db[rng.integers(0, n, m)])

    print(f"building sharded bST: n={n}, shards={n_shards} "
          f"(per-shard build is embarrassingly parallel)")
    t0 = time.time()
    index = build_sharded_bst(db, cfg.b, n_shards)
    print(f"  built in {time.time() - t0:.1f}s; common plan: dense<= "
          f"{index.lm}, collapse at {index.ls}, kinds={index.kinds}")

    searcher = make_sharded_searcher(index, tau)
    t0 = time.time()
    masks, shard_dists, _ = searcher(queries)
    masks = np.asarray(masks)
    dt = time.time() - t0
    ids = gather_ids(index, masks)
    print(f"searched {m} queries in {dt:.2f}s (incl. compile); "
          f"hits: {[len(i) for i in ids]}")

    # distance planes merge into global top-k with no second pass
    # (exact within tau; -1 pads where a query has < k hits in the ball)
    top_ids, top_d = gather_topk(index, np.asarray(shard_dists), k=3)
    print(f"top-3 of query 0: ids={top_ids[0]} dists={top_d[0]}")

    # correctness vs brute force
    dists = np.asarray(hamming_pairwise_naive(queries, jnp.asarray(db)))
    for qi in range(m):
        assert set(ids[qi]) == set(np.flatnonzero(dists[qi] <= tau))
    print("brute-force check: OK")

    # billion-scale projection (paper Table IV: SI-bST 9.6 GiB on SIFT)
    single = build_bst(db[:50_000], cfg.b)
    bytes_per_sketch = single.model_bits() / 8 / 50_000
    proj = bytes_per_sketch * PAPER_DATASETS["sift"].n / 2**30
    print(f"space projection at n=10^9: {proj:.1f} GiB "
          f"({bytes_per_sketch:.1f} B/sketch; paper reports ~9.6 GiB)")


if __name__ == "__main__":
    main()
