#!/usr/bin/env python3
"""Recovery smoke check (CI gate for DESIGN.md §8).

A child process builds durable collections under a data dir — sealed
segment snapshots plus journaled-but-unsealed delta rows and tombstones
— records the answers it expects to survive, syncs the journal, and
then hard-kills itself with ``os._exit``: no ``close()``, no atexit, no
final flush.  That is exactly the crash the write-ahead log protects
against.  The parent re-opens the directory with
``CollectionRegistry.open`` and asserts:

1. **Bit-identical answers**: recovered top-k ids and distances equal
   the child's pre-crash answers for every collection, including rows
   that only ever existed in the journal and deletes of sealed rows.
2. **Collision-free resume**: the id allocator continues exactly where
   the crashed process stopped — new inserts extend, never overwrite.
3. **Replay actually happened**: the store counters show journal
   records were replayed (the test corpus is built so the delta buffer
   is non-empty at the kill).

Unlike the timing benchmarks these are exact-value checks, fully
deterministic on any runner, so this script hard-fails on regression.

Usage: ``PYTHONPATH=src python tools/recovery_smoke.py [n_rows]``
"""

from __future__ import annotations

import os
import subprocess
import sys
import tempfile

import numpy as np

from repro.serving import CollectionConfig, CollectionRegistry

L, B, K, TAIL = 16, 2, 10, 32


def corpus(n: int):
    rng = np.random.default_rng(42)
    db = rng.integers(0, 1 << B, size=(n + TAIL, L), dtype=np.uint8)
    return db, db[:8]


def collections(n: int):
    return {
        "docs": CollectionConfig(L=L, b=B, delta_cap=max(8, n // 4)),
        "stacks": CollectionConfig(L=L, b=B, n_stacks=2,
                                   delta_cap=max(8, n // 8)),
    }


def child(data_dir: str, expected: str, n: int) -> None:
    db, qs = corpus(n)
    reg = CollectionRegistry(data_dir, fsync_every=8)
    out = {}
    for name, cfg in collections(n).items():
        coll = reg.create(name, cfg)
        chunk = max(8, n // 8)
        ids = np.zeros((0,), np.int64)
        for lo in range(0, n, chunk):           # seals segments mid-stream
            ids = np.concatenate([ids, coll.index.insert(db[lo:lo + chunk])])
        coll.index.delete(ids[::7])             # tombstones sealed rows
        coll.index.insert(db[n:n + TAIL])       # tail stays in the delta
        coll.store.wal.sync()                   # durable, but NOT sealed
        nn = coll.index.topk_batch(qs, K)
        out[f"{name}_ids"] = np.asarray(nn.ids)
        out[f"{name}_dists"] = np.asarray(nn.dists)
        out[f"{name}_n_ids"] = coll.index.n_ids
        out[f"{name}_n_live"] = coll.index.n_live
    np.savez(expected, **out)
    sys.stdout.flush()
    os._exit(17)                                # crash: no close, no flush


def main(argv=None) -> int:
    argv = argv if argv is not None else sys.argv[1:]
    if argv and argv[0] == "--child":
        child(argv[1], argv[2], int(argv[3]))
        return 0                                # unreachable (os._exit)
    n = int(argv[0]) if argv else 2048

    with tempfile.TemporaryDirectory(prefix="recovery_smoke_") as tmp:
        data = os.path.join(tmp, "data")
        expected = os.path.join(tmp, "expected.npz")
        env = dict(os.environ)
        env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
        proc = subprocess.run(
            [sys.executable, os.path.abspath(__file__),
             "--child", data, expected, str(n)], env=env)
        assert proc.returncode == 17, \
            f"child died before the staged kill: rc={proc.returncode}"
        exp = np.load(expected)

        db, qs = corpus(n)
        reg = CollectionRegistry.open(data)
        assert reg.names() == sorted(collections(n)), reg.names()
        for name in reg.names():
            coll = reg.get(name)
            nn = coll.index.topk_batch(qs, K)
            np.testing.assert_array_equal(np.asarray(nn.ids),
                                          exp[f"{name}_ids"])
            np.testing.assert_array_equal(np.asarray(nn.dists),
                                          exp[f"{name}_dists"])
            assert coll.index.n_ids == int(exp[f"{name}_n_ids"])
            assert coll.index.n_live == int(exp[f"{name}_n_live"])
            st = coll.store.stats()
            assert st["replayed_records"] > 0, (name, st)
            # the allocator resumes collision-free past the crash
            n0 = coll.index.n_ids
            new = coll.index.insert(db[:3])
            np.testing.assert_array_equal(new, [n0, n0 + 1, n0 + 2])
            print(f"{name}: n_live={coll.index.n_live} "
                  f"replayed={st['replayed_records']} "
                  f"segments_recovered={st['recovered_segments']}")
        reg.close()
    print("recovery smoke OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
