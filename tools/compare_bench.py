#!/usr/bin/env python3
"""Compare a fresh ``benchmarks/run.py --out`` JSON against a committed
seed baseline (``BENCH_*.json``) — the perf-trajectory check.

Usage: ``python tools/compare_bench.py BASELINE.json CURRENT.json``

Matches rows by name and prints the per-row us_per_call ratio
(current / baseline).  Rows whose derived fields carry
``bytes_per_row_device`` / ``bytes_per_row_host`` (the capacity rows of
``bench_ingest``) get a second table tracking the space trajectory —
unlike timings, byte counts are deterministic, so a capacity regression
is a real layout change, not runner noise.  Exits non-zero only on
*structural* regressions — a baseline row that no longer exists in the
current run (a benchmark silently dropped) — because absolute timings
on shared CI runners are too noisy to gate on; the ratio tables in the
job log and the uploaded artifacts are the trajectory.
"""

from __future__ import annotations

import json
import sys


def load_rows(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    return {row["name"]: row for row in doc.get("rows", [])}


def main(argv=None) -> int:
    argv = argv if argv is not None else sys.argv[1:]
    if len(argv) != 2:
        print(__doc__)
        return 2
    base, cur = load_rows(argv[0]), load_rows(argv[1])
    missing = sorted(set(base) - set(cur))
    new = sorted(set(cur) - set(base))
    print(f"# baseline {argv[0]}: {len(base)} rows; "
          f"current {argv[1]}: {len(cur)} rows")
    print("name,baseline_us,current_us,ratio")
    for name in sorted(set(base) & set(cur)):
        b = float(base[name]["us_per_call"]) or 1e-9
        c = float(cur[name]["us_per_call"])
        print(f"{name},{b:.2f},{c:.2f},{c / b:.2f}")
    for name in new:
        print(f"{name},-,{cur[name]['us_per_call']:.2f},new")
    bpr_rows = sorted(
        name for name in cur
        if "bytes_per_row_device" in cur[name].get("derived", {}))
    if bpr_rows:
        print("name,tier,baseline_bytes_per_row,current_bytes_per_row")
        for name in bpr_rows:
            for tier in ("device", "host"):
                key = f"bytes_per_row_{tier}"
                c = cur[name]["derived"].get(key)
                b = base.get(name, {}).get("derived", {}).get(key, "-")
                print(f"{name},{tier},{b},{c}")
    if missing:
        print(f"STRUCTURAL REGRESSION: rows missing from current run: "
              f"{missing}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
