#!/usr/bin/env python
"""Markdown link checker (offline): every relative link/image target in
the repo's *.md files must exist on disk, and every intra-repo anchor
(`file.md#section`) must match a heading in the target file.

    python tools/check_links.py [root]

External (http/https/mailto) links are skipped — CI has no network and
examples must not rot for reachability reasons; what this job pins down
is the *internal* docs graph (README ↔ DESIGN.md ↔ docs/API.md ↔ code
paths referenced as links).  Exit code 1 on any broken target.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
SKIP_DIRS = {".git", ".pytest_cache", "__pycache__", "node_modules"}
SKIP_SCHEMES = ("http://", "https://", "mailto:", "ftp://")


def slugify(heading: str) -> str:
    """GitHub-style anchor slug (lowercase, strip punctuation, dashes)."""
    text = re.sub(r"[`*_]", "", heading.strip().lower())
    text = re.sub(r"[^\w\- ]", "", text, flags=re.UNICODE)
    return text.replace(" ", "-")


def md_files(root: Path):
    for path in sorted(root.rglob("*.md")):
        if not any(part in SKIP_DIRS for part in path.parts):
            yield path


def anchors_of(path: Path) -> set:
    return {slugify(h) for h in HEADING_RE.findall(
        path.read_text(encoding="utf-8"))}


def check(root: Path) -> int:
    errors = []
    for md in md_files(root):
        text = md.read_text(encoding="utf-8")
        for match in LINK_RE.finditer(text):
            target = match.group(1)
            if target.startswith(SKIP_SCHEMES):
                continue
            path_part, _, anchor = target.partition("#")
            base = md if not path_part else (md.parent / path_part)
            if path_part:
                if not base.exists():
                    errors.append(f"{md}: broken link -> {target}")
                    continue
            if anchor and base.suffix == ".md" and base.exists():
                if slugify(anchor) not in anchors_of(base):
                    errors.append(f"{md}: missing anchor -> {target}")
    for err in errors:
        print(err)
    n = len(list(md_files(root)))
    print(f"checked {n} markdown files: "
          f"{'FAILED (' + str(len(errors)) + ' broken)' if errors else 'OK'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(check(Path(sys.argv[1] if len(sys.argv) > 1 else ".")))
