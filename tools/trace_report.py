#!/usr/bin/env python
"""Validate and summarize a serving trace (DESIGN.md §11).

Input is what ``python -m repro.launch.serve --trace-dir DIR`` wrote: a
Chrome trace-event JSON (``trace.json`` — "X" complete events, one tid
per lane) plus an optional ``slow_queries.jsonl``.  The report:

  * validates the event array is well-formed Chrome trace JSON and that
    events nest properly per tid (a tid is a stack in the trace model);
  * reconstructs per-request trees (request root -> queue_wait -> the
    shared batch span with its assembly / execute / rung_dispatch /
    rerank / respond phases);
  * prints e2e p50/p99 and the phase breakdown of the p99 request —
    queue_wait + batch must cover its end-to-end time;
  * summarizes the slow-query log when present.

``--check`` turns the report into a gate (CI ``obs-smoke``): exit 1
unless the file loads, nests, and holds at least one complete request
tree whose queue_wait + batch spans cover >= 90% of its e2e time.

Usage: ``python tools/trace_report.py TRACE_DIR_or_trace.json [--check]``
"""

from __future__ import annotations

import argparse
import json
import os
import sys

EPS_US = 0.01          # rounding slack: durations carry ns precision


def load_events(path: str):
    if os.path.isdir(path):
        path = os.path.join(path, "trace.json")
    with open(path) as f:
        events = json.load(f)
    if not isinstance(events, list):
        raise ValueError("trace is not a Chrome event array")
    for ev in events:
        if not isinstance(ev, dict) or "ph" not in ev:
            raise ValueError(f"malformed event: {ev!r}")
        if ev["ph"] == "X" and not ("ts" in ev and "dur" in ev):
            raise ValueError(f"X event without ts/dur: {ev!r}")
    return path, events


def check_nesting(events) -> int:
    """Per tid, X events must properly nest (no partial overlap).
    Returns the number of lanes checked; raises ValueError on overlap."""
    lanes = {}
    for ev in events:
        if ev["ph"] == "X":
            lanes.setdefault(ev.get("tid", 0), []).append(ev)
    for tid, evs in lanes.items():
        evs.sort(key=lambda e: (e["ts"], -e["dur"]))
        stack = []
        for ev in evs:
            end = ev["ts"] + ev["dur"]
            while stack and ev["ts"] >= stack[-1] - EPS_US:
                stack.pop()
            if stack and end > stack[-1] + EPS_US:
                raise ValueError(
                    f"tid {tid}: event {ev['name']!r} overlaps its "
                    f"enclosing span ({end:.3f} > {stack[-1]:.3f} us)")
            stack.append(end)
    return len(lanes)


def _by_name(events, name):
    return [e for e in events if e["ph"] == "X" and e["name"] == name]


def request_trees(events):
    """[(request, queue_wait|None, batch|None)] — queue_wait shares the
    request's lane; the batch span starts where the queue wait ends and
    finishes with the request."""
    batches = _by_name(events, "batch")
    trees = []
    for req in _by_name(events, "request"):
        qw = next((e for e in _by_name(events, "queue_wait")
                   if e.get("tid") == req.get("tid")), None)
        batch = None
        if qw is not None and batches:
            t_pop = qw["ts"] + qw["dur"]
            t_end = req["ts"] + req["dur"]
            batch = min(batches, key=lambda b: abs(b["ts"] - t_pop)
                        + abs(b["ts"] + b["dur"] - t_end))
            if (abs(batch["ts"] - t_pop) > 1e3        # > 1 ms off: not ours
                    or abs(batch["ts"] + batch["dur"] - t_end) > 1e3):
                batch = None
        trees.append((req, qw, batch))
    return trees


def contained(events, outer):
    lo, hi = outer["ts"] - EPS_US, outer["ts"] + outer["dur"] + EPS_US
    return [e for e in events
            if e["ph"] == "X" and e is not outer
            and e["ts"] >= lo and e["ts"] + e["dur"] <= hi
            and e.get("tid") == outer.get("tid")]


def percentile(vals, p):
    if not vals:
        return 0.0
    vals = sorted(vals)
    i = min(len(vals) - 1, int(round(p / 100 * (len(vals) - 1))))
    return vals[i]


def report(path: str, check: bool) -> int:
    path, events = load_events(path)
    lanes = check_nesting(events)
    trees = request_trees(events)
    xs = [e for e in events if e["ph"] == "X"]
    print(f"{path}: {len(xs)} spans on {lanes} lanes, "
          f"{len(trees)} requests")
    if not trees:
        print("no request spans found")
        return 1 if check else 0

    e2e = [t[0]["dur"] / 1e3 for t in trees]        # ms
    print(f"request e2e: p50={percentile(e2e, 50):.3f} ms  "
          f"p99={percentile(e2e, 99):.3f} ms  "
          f"max={max(e2e):.3f} ms")

    complete = 0
    p99_req = max(trees, key=lambda t: t[0]["dur"])
    for req, qw, batch in trees:
        if qw is not None and batch is not None:
            complete += 1
    print(f"complete trees (request + queue_wait + batch): "
          f"{complete}/{len(trees)}")

    req, qw, batch = p99_req
    if qw is not None and batch is not None:
        covered = qw["dur"] + batch["dur"]
        frac = covered / req["dur"] if req["dur"] else 0.0
        print(f"slowest request ({req['dur'] / 1e3:.3f} ms, "
              f"op={req['args'].get('op')}):")
        print(f"  queue_wait      {qw['dur'] / 1e3:9.3f} ms")
        phases = contained(events, batch)
        for ph in phases:
            label = ph["name"]
            if ph["name"] == "rung_dispatch":
                label += f" tau={ph['args'].get('tau')}"
            print(f"  {label:15s} {ph['dur'] / 1e3:9.3f} ms")
        print(f"  coverage: (queue_wait + batch) / e2e = {frac:.3f}")
        if check and frac < 0.9:
            print("CHECK FAILED: span coverage < 90% of e2e")
            return 1
    elif check:
        print("CHECK FAILED: slowest request has no complete span tree")
        return 1

    slow_path = os.path.join(os.path.dirname(path), "slow_queries.jsonl")
    if os.path.exists(slow_path):
        with open(slow_path) as f:
            entries = [json.loads(line) for line in f if line.strip()]
        print(f"slow-query log: {len(entries)} entries in {slow_path}")
        for e in sorted(entries, key=lambda e: -e["e2e_ms"])[:3]:
            print(f"  {e['e2e_ms']:.3f} ms op={e.get('op')} "
                  f"collection={e.get('collection')}")

    if check and complete == 0:
        print("CHECK FAILED: no complete request tree")
        return 1
    if check:
        print("CHECK OK")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("path", help="trace dir (containing trace.json) or a "
                                 "trace JSON file")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless the trace validates and holds a "
                         "complete request tree covering >=90% of e2e")
    args = ap.parse_args(argv)
    try:
        return report(args.path, args.check)
    except (ValueError, OSError, json.JSONDecodeError) as e:
        print(f"invalid trace: {e}")
        return 1


if __name__ == "__main__":
    sys.exit(main())
