#!/usr/bin/env python3
"""Capacity smoke check (CI gate for DESIGN.md §7).

Builds the same fixed corpus (the paper's review geometry, L=16, b=2)
under both sealed-column layouts and asserts the two deterministic
capacity claims of the tiered column store:

1. **Suffix beats full-length**: the packed suffix layout spends at
   most half the device column bytes of the full-length arena
   (integer-exact on any geometry with b*(L - l_s) <= 32).
2. **Cold tier stays one-dispatch**: with a hot-tier budget of zero —
   a corpus strictly larger than the device budget — queries still
   answer bit-identically at the same fused launch count as the
   all-hot store, with zero per-segment fan-out.

Unlike the timing benchmarks these are byte/launch *counts*, fully
deterministic on any runner, so this script hard-fails on regression.

Usage: ``PYTHONPATH=src python tools/capacity_smoke.py [n_rows]``
"""

from __future__ import annotations

import sys

import numpy as np

from repro.core import SegmentedIndex, dispatch_stats, reset_dispatch_stats

L, B, SEGMENTS = 16, 2, 4


def build(n: int, **kw):
    rng = np.random.default_rng(42)
    db = rng.integers(0, 1 << B, size=(n, L), dtype=np.uint8)
    idx = SegmentedIndex(L, B, delta_cap=n + 1, auto_merge=False, **kw)
    chunk = n // SEGMENTS
    for lo in range(0, SEGMENTS * chunk, chunk):
        idx.insert(db[lo:lo + chunk])
        idx.flush()
    return idx, db


def main(argv=None) -> int:
    argv = argv if argv is not None else sys.argv[1:]
    n = int(argv[0]) if argv else 2048
    qs_slice = slice(0, 8)
    k = 10

    suffix, db = build(n, layout="suffix")
    full, _ = build(n, layout="full")
    qs = db[qs_slice]
    r_sfx, r_full = suffix.topk_batch(qs, k), full.topk_batch(qs, k)
    np.testing.assert_array_equal(np.asarray(r_sfx.ids),
                                  np.asarray(r_full.ids))
    np.testing.assert_array_equal(np.asarray(r_sfx.dists),
                                  np.asarray(r_full.dists))
    sfx_bytes = suffix._refresh_store().col_bytes()
    full_bytes = full._refresh_arena().col_bytes()
    print(f"column bytes: suffix={sfx_bytes} full={full_bytes} "
          f"ratio={full_bytes / sfx_bytes:.2f}x "
          f"({sfx_bytes / n:.2f} vs {full_bytes / n:.2f} B/row)")
    assert full_bytes >= 2 * sfx_bytes, \
        f"suffix layout must at least halve column bytes: " \
        f"{sfx_bytes} vs {full_bytes}"

    reset_dispatch_stats()
    suffix.topk_batch(qs, k)
    hot_disp = dispatch_stats()

    cold, _ = build(n, layout="suffix", hot_bytes=0)
    r_cold = cold.topk_batch(qs, k)           # warm (stage + compiles)
    np.testing.assert_array_equal(np.asarray(r_cold.ids),
                                  np.asarray(r_sfx.ids))
    np.testing.assert_array_equal(np.asarray(r_cold.dists),
                                  np.asarray(r_sfx.dists))
    reset_dispatch_stats()
    cold.topk_batch(qs, k)
    cold_disp = dispatch_stats()
    tier = cold.stats()["tier"]
    print(f"cold tier: {tier}; dispatches hot={hot_disp} cold={cold_disp}")
    assert tier["hot_blocks"] == 0 and tier["cold_blocks"] == SEGMENTS, tier
    assert cold_disp["fanout"] == 0, cold_disp
    assert cold_disp["total"] == cold_disp["fused"] == hot_disp["fused"], \
        (hot_disp, cold_disp)
    print("capacity smoke OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
