#!/usr/bin/env python
"""Chaos/overload harness for the serving control plane (DESIGN.md §12).

Four phases, each a hard gate (the SLO is CI-enforced, not aspirational):

  A. **Burst SLO** — one tenant ("victim") fires a 10× open-loop burst
     while a ``SlowDispatchInjector`` stalls every one of its dispatches
     (the "device got slow for this tenant" fault); two well-behaved
     co-tenant clients run closed-loop beside it.  Asserts: co-tenant
     goodput (answers within deadline) ≥ 0.9, victim p99 ≤ 10× its p50
     (deadlines bound the tail — overload degrades *bounded*, not
     unbounded), at least one queued victim request was cancelled by
     deadline, and every degraded response names its ladder stage.
  B. **Expired-never-dispatch** — requests whose deadline has already
     passed are cancelled with zero device launches, asserted with the
     ``dispatch_stats()`` spy.
  C. **Degrade determinism** — at a forced pressure level the scheduler
     answers with degraded parameters, the response says so, and the
     answer is bit-identical to calling the index directly at the same
     effective (k, τ0) / τ — degradation changes parameters, never
     kernels.
  D. **Breaker lifecycle** — closed → open (repeated deadline blowouts)
     → rejecting with ``retry_after_ms`` → half-open probing → closed,
     both on a fake clock and through a live scheduler.

Usage: ``PYTHONPATH=src python tools/overload_smoke.py [--smoke]
[--out overload_smoke.json]``.  Exit code 0 iff every gate holds.
"""

from __future__ import annotations

import argparse
import json
import sys
import threading
import time

import numpy as np

from repro.core.segments import dispatch_stats
from repro.serving import (AdmissionConfig, BreakerConfig, CircuitBreaker,
                           CollectionConfig, DeadlineExceeded, DegradePolicy,
                           OverloadError, Scheduler, SchedulerConfig,
                           SlowDispatchInjector)

L, B = 16, 2
POLICY = DegradePolicy()


def _corpus(n: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(0, 1 << B, size=(n, L), dtype=np.uint8)


def _make_sched(faults=None, breaker=True, capacity=1024.0,
                max_queue=4096) -> Scheduler:
    # interval_ms=50: escalation needs ~3 interval closes (pops) past a
    # standing queue, and the ladder must engage well inside the
    # deadline even when a loaded CI box stretches the batch period
    return Scheduler(config=SchedulerConfig(
        max_batch=8, max_queue=max_queue, max_wait_ms=1.0,
        admission=AdmissionConfig(cost_capacity=capacity,
                                  interval_ms=50.0),
        degrade=POLICY,
        breaker=BreakerConfig(window=32, min_samples=16, fail_frac=0.5,
                              open_ms=100.0, probes=2) if breaker
        else None), faults=faults)


# ---------------------------------------------------------------------------
# phase A: 10x burst + slow-dispatch faults, multi-tenant SLO
# ---------------------------------------------------------------------------

def run_burst(n_docs: int = 2048, burst: int = 160, k: int = 10,
              deadline_ms: float = 800.0, fault_s: float = 0.04,
              cotenant_clients: int = 2, cotenant_ops: int = 30,
              seed: int = 0) -> dict:
    """The burst scenario; returns the measured SLO dict (also consumed
    by ``benchmarks.bench_serving`` for the ``burst_*`` rows).  The
    burst is ~10× the co-tenant offered load: ``burst`` one-shot
    requests vs ``cotenant_clients * cotenant_ops`` closed-loop ones,
    with every victim dispatch stalled ``fault_s`` seconds."""
    docs = _corpus(n_docs, seed)
    inj = SlowDispatchInjector(delay_s=fault_s, match="execute:victim")
    sched = _make_sched(faults=inj)
    sched.create_collection("victim", CollectionConfig(L=L, b=B))
    sched.create_collection("cotenant", CollectionConfig(L=L, b=B))
    f1 = sched.submit_insert("victim", docs)
    f2 = sched.submit_insert("cotenant", docs)
    sched.pump()
    f1.result(), f2.result()
    sched.warmup(ks=(k,))               # compiles never pollute the SLO
    sched.start()

    # victim: open-loop 10x burst under slow-dispatch faults.  Outcomes
    # land via done-callbacks (an open-loop client never waits).
    vic_lock = threading.Lock()
    vic_lat: list = []                  # (seconds, ok, degraded_stage)
    vic_shed = 0
    rng = np.random.default_rng(seed + 1)
    pending = []
    for i in range(burst):
        q = docs[rng.integers(0, n_docs)]
        t0 = time.perf_counter()
        try:
            fut = sched.submit_topk("victim", q, k, deadline_ms=deadline_ms)
        except OverloadError as e:
            assert e.retry_after_ms >= 0.0
            vic_shed += 1
            continue

        def _done(f, t0=t0):
            lat = time.perf_counter() - t0
            exc = f.exception()
            stage = None if exc is not None else f.result().degraded
            ok = exc is None and lat * 1e3 <= deadline_ms
            with vic_lock:
                vic_lat.append((lat, ok, stage))

        fut.add_done_callback(_done)
        pending.append(fut)

    # co-tenants: closed-loop, same deadline, their own collection —
    # the victim's burst must not eat their latency budget
    co_ok, co_total, co_errors = [0], [0], []

    def _cotenant(cid: int) -> None:
        crng = np.random.default_rng(seed + 100 + cid)
        for _ in range(cotenant_ops):
            q = docs[crng.integers(0, n_docs)]
            t0 = time.perf_counter()
            co_total[0] += 1
            try:
                r = sched.submit_topk("cotenant", q, k,
                                      deadline_ms=deadline_ms)
                r.result(timeout=60)
                if (time.perf_counter() - t0) * 1e3 <= deadline_ms:
                    co_ok[0] += 1
            except (DeadlineExceeded, OverloadError):
                pass
            except Exception as e:     # noqa: BLE001
                co_errors.append(e)
                return

    threads = [threading.Thread(target=_cotenant, args=(c,))
               for c in range(cotenant_clients)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    deadline_wall = time.time() + 120
    for fut in pending:
        try:
            fut.result(timeout=max(deadline_wall - time.time(), 1))
        except Exception:              # noqa: BLE001 — outcome recorded
            pass                       # by the done-callback
    sched.stop()
    if co_errors:
        raise co_errors[0]

    snap = sched.stats()
    lats = np.asarray([s for s, _, _ in vic_lat])
    degraded = [st for _, _, st in vic_lat if st is not None]
    out = {
        "burst": burst,
        "victim_shed": vic_shed,
        "victim_completed": len(vic_lat),
        "victim_ok": sum(1 for _, ok, _ in vic_lat if ok),
        "victim_p50_ms": float(np.percentile(lats, 50)) * 1e3,
        "victim_p99_ms": float(np.percentile(lats, 99)) * 1e3,
        "cotenant_total": co_total[0],
        "cotenant_ok": co_ok[0],
        "goodput": co_ok[0] / max(co_total[0], 1),
        "degraded": len(degraded),
        "degraded_frac": len(degraded) / max(len(vic_lat), 1),
        "degraded_stages": sorted(set(degraded)),
        "deadline_exceeded":
            snap["counters"].get("deadline_exceeded_total", 0),
        "breaker_trips": sum(
            d.get("breaker_trips", 0)
            for d in snap.get("overload", {}).values()),
        "stopped_dirty": snap["stopped_dirty"],
    }
    out["victim_p99_ratio"] = out["victim_p99_ms"] \
        / max(out["victim_p50_ms"], 1e-6)
    return out


def check_burst(res: dict) -> None:
    # the SLO (smoke thresholds, ISSUE acceptance): co-tenants keep
    # >= 90% goodput and the victim's own tail stays deadline-bounded
    assert res["goodput"] >= 0.9, res
    assert res["victim_p99_ratio"] <= 10.0, res
    assert res["deadline_exceeded"] >= 1, res
    assert res["victim_completed"] + res["victim_shed"] == res["burst"], res
    # the ladder must actually engage (the fault sleep floors the pop
    # cadence, so CoDel reaches shrink_k well inside the deadline) and
    # every degraded answer must name its stage
    assert res["degraded"] >= 1, res
    for stage in res["degraded_stages"]:
        assert stage in POLICY.stages, res
    assert not res["stopped_dirty"], res


# ---------------------------------------------------------------------------
# phase B: expired requests never reach the device
# ---------------------------------------------------------------------------

def run_expired_never_dispatch(n_docs: int = 512, n_req: int = 16) -> dict:
    docs = _corpus(n_docs, 7)
    sched = _make_sched(breaker=False)
    sched.create_collection("docs", CollectionConfig(L=L, b=B))
    sched.submit_insert("docs", docs)
    sched.pump()
    futs = [sched.submit_topk("docs", docs[i], 5, deadline_ms=0.01)
            for i in range(n_req)]
    time.sleep(0.01)                    # every budget is now blown
    before = dispatch_stats()
    sched.pump()                        # the dispatch_stats() spy: the
    after = dispatch_stats()            # purge must launch NOTHING
    cancelled = 0
    for f in futs:
        try:
            f.result(timeout=5)
        except DeadlineExceeded as e:
            assert e.retry_after_ms >= 0.0 and e.deadline_ms is not None
            cancelled += 1
    return {"requests": n_req, "cancelled": cancelled,
            "dispatch_delta": after["total"] - before["total"]}


def check_expired(res: dict) -> None:
    assert res["cancelled"] == res["requests"], res
    assert res["dispatch_delta"] == 0, res


# ---------------------------------------------------------------------------
# phase C: degraded answers are labelled and bit-identical
# ---------------------------------------------------------------------------

def run_degrade_identity(n_docs: int = 512) -> dict:
    docs = _corpus(n_docs, 11)
    sched = _make_sched(breaker=False)
    sched.create_collection("docs", CollectionConfig(L=L, b=B))
    sched.submit_insert("docs", docs)
    sched.pump()
    idx = sched.registry.get("docs").index
    ctrl = sched._states["docs"].ctrl

    def _force_level(level: int, start: float) -> None:
        # fabricate a sustained standing queue with timestamps far in
        # the future so live pops (which sample near-zero delays at the
        # real clock) can never close an interval underneath the check.
        # The first sample only opens (or flushes) an interval; each of
        # the next ``level`` samples closes one bad interval.
        for i in range(level + 1):
            ctrl.note_delay(0.05, now=start + 0.11 * i)

    far = time.perf_counter() + 1e9
    _force_level(2, far)
    level = ctrl.pressure()
    assert level == 2, level            # rerank_off + shrink_k active

    q = docs[3]
    fut = sched.submit_topk("docs", q, 10)
    sched.pump()
    res = fut.result(timeout=60)
    k_eff, tau0_eff, _, stage = POLICY.apply_topk(level, 10, None, None)
    direct = idx.topk_batch(q[None, :], k_eff, tau0=tau0_eff)
    topk_identical = (res.degraded == stage == "shrink_k"
                      and np.array_equal(res.ids,
                                         np.asarray(direct.ids)[0])
                      and np.array_equal(res.dists,
                                         np.asarray(direct.dists)[0]))

    _force_level(3, far + 1000.0)                  # level 3: cheap_tau
    fut = sched.submit_search("docs", q, tau=4)
    sched.pump()                       # NB: draining calls note_empty()
    sres = fut.result(timeout=60)      # which resets pressure to 0, so
    tau_eff, sstage = POLICY.apply_search(3, 4)    # use the forced level
    sdirect = idx.search_batch(q[None, :], tau_eff)
    search_identical = (sres.degraded == sstage == "cheap_tau"
                        and np.array_equal(sres.mask,
                                           np.asarray(sdirect.mask)[0]))
    degraded_ctr = sched.stats()["counters"].get("degraded_total", 0)
    return {"level": level, "topk_stage": res.degraded,
            "topk_identical": bool(topk_identical),
            "search_stage": sres.degraded,
            "search_identical": bool(search_identical),
            "degraded_total": degraded_ctr}


def check_degrade(res: dict) -> None:
    assert res["topk_identical"], res
    assert res["search_identical"], res
    assert res["degraded_total"] >= 2, res


# ---------------------------------------------------------------------------
# phase D: breaker lifecycle (fake clock + live scheduler)
# ---------------------------------------------------------------------------

def run_breaker_lifecycle(n_docs: int = 256) -> dict:
    # fake-clock state machine: closed -> open -> half_open -> closed
    clock = [0.0]
    br = CircuitBreaker(BreakerConfig(window=8, min_samples=4,
                                      fail_frac=0.5, open_ms=100.0,
                                      probes=2), clock=lambda: clock[0])
    states = [br.state()]
    for _ in range(4):
        br.record(False)
    states.append(br.state())           # tripped open
    allowed, retry = br.allow()
    assert not allowed and retry > 0.0
    clock[0] += 0.15                    # open window elapses
    states.append(br.state())           # half_open
    assert br.allow()[0] and br.allow()[0]      # two probe slots
    assert not br.allow()[0]                    # budget spent
    br.record(True)
    br.record(True)
    states.append(br.state())           # probes succeeded -> closed

    # live scheduler: deadline blowouts trip the collection's breaker,
    # submits shed with retry_after_ms, probing closes it again
    docs = _corpus(n_docs, 13)
    sched = Scheduler(config=SchedulerConfig(
        max_batch=8, max_queue=4096, max_wait_ms=1.0,
        admission=AdmissionConfig(cost_capacity=1024.0),
        breaker=BreakerConfig(window=8, min_samples=4, fail_frac=0.5,
                              open_ms=50.0, probes=2)))
    sched.create_collection("docs", CollectionConfig(L=L, b=B))
    sched.submit_insert("docs", docs)
    sched.pump()
    for i in range(8):
        sched.submit_topk("docs", docs[i], 5, deadline_ms=0.01)
    time.sleep(0.01)
    sched.pump()                        # purge -> 8 failures -> OPEN
    live_open = sched._states["docs"].breaker.state()
    shed_reason = None
    try:
        sched.submit_topk("docs", docs[0], 5)
    except OverloadError as e:
        shed_reason = e.reason
        assert e.retry_after_ms > 0.0
    time.sleep(0.08)                    # open window elapses
    for _ in range(2):                  # half-open probes succeed
        f = sched.submit_topk("docs", docs[0], 5)
        sched.pump()
        f.result(timeout=60)
    live_closed = sched._states["docs"].breaker.state()
    return {"fake_states": states, "live_open": live_open,
            "shed_reason": shed_reason, "live_closed": live_closed}


def check_breaker(res: dict) -> None:
    assert res["fake_states"] == ["closed", "open", "half_open",
                                  "closed"], res
    assert res["live_open"] == "open", res
    assert res["shed_reason"] == "breaker_open", res
    assert res["live_closed"] == "closed", res


# ---------------------------------------------------------------------------

def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="smaller corpus/burst (CI-sized; same gates)")
    ap.add_argument("--out", default=None,
                    help="write the phase reports as JSON here")
    args = ap.parse_args(argv)

    burst_kw = dict(n_docs=1024, burst=120) if args.smoke else {}
    report = {}
    t0 = time.time()
    report["burst"] = run_burst(**burst_kw)
    check_burst(report["burst"])
    print(f"A burst SLO: goodput={report['burst']['goodput']:.3f} "
          f"victim p99/p50={report['burst']['victim_p99_ratio']:.1f} "
          f"deadline_exceeded={report['burst']['deadline_exceeded']} "
          f"degraded={report['burst']['degraded']} "
          f"{report['burst']['degraded_stages']} "
          f"breaker_trips={report['burst']['breaker_trips']}")
    report["expired"] = run_expired_never_dispatch()
    check_expired(report["expired"])
    print(f"B expired-never-dispatch: {report['expired']['cancelled']} "
          f"cancelled, dispatch_delta={report['expired']['dispatch_delta']}")
    report["degrade"] = run_degrade_identity()
    check_degrade(report["degrade"])
    print(f"C degrade identity: topk stage={report['degrade']['topk_stage']}"
          f" search stage={report['degrade']['search_stage']} "
          f"bit-identical={report['degrade']['topk_identical'] and report['degrade']['search_identical']}")
    report["breaker"] = run_breaker_lifecycle()
    check_breaker(report["breaker"])
    print(f"D breaker lifecycle: {' -> '.join(report['breaker']['fake_states'])}"
          f" (live: {report['breaker']['live_open']} -> "
          f"{report['breaker']['live_closed']})")
    print(f"overload smoke: ALL GATES PASS in {time.time() - t0:.1f}s")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
