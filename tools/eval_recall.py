#!/usr/bin/env python3
"""Recall eval harness for two-stage retrieval (DESIGN.md §10).

Builds a seeded synthetic token-set corpus with *known exact-Jaccard
ground truth*, sketches it with b-bit minwise hashing for b in {1, 2, 4}
(the Li & König accuracy/space trade-off curve), and measures recall@k
through the real index for both stages:

  * ``sketch``   — stage 1 only: top-k by sketch Hamming distance
  * ``reranked`` — two-stage: same trie survivors, exact-Jaccard
                   re-rank (``topk(rerank="jaccard")``)

Ground truth is the exact Jaccard top-k over the whole corpus (ties
broken by id, the same order the re-rank select uses), so the reranked
recall is provably the ceiling the survivor set allows: any ground-truth
row the sketch stage keeps alive is re-ranked back into the top-k.

Usage::

    PYTHONPATH=src python tools/eval_recall.py [--smoke] [--check]
        [--out recall.json]

``--check`` exits non-zero unless, for every b, reranked recall@k >=
sketch-only recall@k and reranked recall@k >= the fixed floor — the CI
``recall-smoke`` gate.
"""

from __future__ import annotations

import argparse
import json
import sys

import numpy as np

from repro.core import SegmentedIndex
from repro.core.hamming import pack_sets

# the CI gate: two-stage recall@10 on the smoke corpus must not sink
# below this floor (seeded corpus -> deterministic up to f32 scoring)
RECALL_FLOOR = 0.60

_MERSENNE = (1 << 61) - 1


def build_corpus(rng, n_docs, vocab, set_min=8, set_max=40):
    """Token-id sets with planted near-duplicate structure: each doc is
    a fresh random set, queries are perturbed copies (drop + add a few
    tokens) so exact-Jaccard neighbourhoods are non-trivial."""
    return [rng.choice(vocab, size=int(rng.integers(set_min, set_max)),
                       replace=False) for _ in range(n_docs)]


def perturb(rng, s, vocab, frac=0.25):
    s = set(int(t) for t in s)
    n_swap = max(1, int(len(s) * frac))
    drop = rng.choice(sorted(s), size=min(n_swap, len(s) - 1),
                      replace=False)
    s -= set(int(t) for t in drop)
    while len(drop) and True:
        add = int(rng.integers(0, vocab))
        s.add(add)
        if len(s) >= n_swap:
            break
    return np.array(sorted(s), np.int64)


def minhash_sketch(sets, L, b, vocab, seed=0):
    """b-bit minwise hashing: L independent universal hash functions,
    keep the low b bits of each min-hash (Li & König)."""
    rng = np.random.default_rng(seed)
    a = rng.integers(1, _MERSENNE, size=L, dtype=np.int64)
    c = rng.integers(0, _MERSENNE, size=L, dtype=np.int64)
    out = np.zeros((len(sets), L), np.uint8)
    for i, s in enumerate(sets):
        t = np.asarray(s, np.int64)[:, None]                 # (|s|, 1)
        h = (t * a[None, :] + c[None, :]) % _MERSENNE        # (|s|, L)
        out[i] = (h.min(axis=0) & ((1 << b) - 1)).astype(np.uint8)
    return out


def exact_jaccard_topk(q_pays, doc_pays, k):
    """Ground truth: exact Jaccard over payload bitmaps, (score desc,
    id asc) — the re-rank select's exact ordering."""
    def pop(x):
        return np.unpackbits(np.ascontiguousarray(x, np.uint32)
                             .view(np.uint8), axis=-1).sum(axis=-1)
    inter = pop(q_pays[:, None, :] & doc_pays[None, :, :]).astype(np.float64)
    union = (pop(q_pays).astype(np.float64)[:, None]
             + pop(doc_pays).astype(np.float64)[None, :] - inter)
    jac = np.where(union > 0, inter / np.maximum(union, 1), 0.0)
    n = doc_pays.shape[0]
    order = np.lexsort((np.arange(n)[None, :].repeat(len(q_pays), 0),
                        -jac))                               # score desc, id asc
    return order[:, :k]


def recall_at_k(retrieved, truth):
    """Mean |retrieved ∩ truth| / k over queries (−1 pads never match)."""
    hits = sum(len(set(map(int, r)) & set(map(int, t)))
               for r, t in zip(retrieved, truth))
    return hits / float(truth.size)


def evaluate(n_docs=2000, n_queries=40, vocab=256, L=48, bs=(1, 2, 4),
             k=10, seed=0, delta_cap=512, cand_mult=10):
    """Run the sweep; returns ``{"k": k, "rows": [{b, sketch, reranked,
    tau_star}, ...]}``.

    The two-stage path uses the candidate-pool knob: stage 1 runs the
    ladder until ``cand_mult * k`` survivors, stage 2 exact-scores every
    survivor, and the report keeps the top k.  Because the survivor set
    only grows with τ and stage 2 ranks by the *true* metric, reranked
    recall@k equals the survivor-coverage ceiling — it can never fall
    below the sketch-only recall at the same k."""
    rng = np.random.default_rng(seed)
    docs = build_corpus(rng, n_docs, vocab)
    queries = [perturb(rng, docs[int(rng.integers(0, n_docs))], vocab)
               if i % 2 == 0 else build_corpus(rng, 1, vocab)[0]
               for i in range(n_queries)]
    doc_pays = pack_sets(docs, vocab)
    q_pays = pack_sets(queries, vocab)
    truth = exact_jaccard_topk(q_pays, doc_pays, k)
    Wp = doc_pays.shape[1]
    rows = []
    for b in bs:
        sk = minhash_sketch(docs, L, b, vocab, seed=seed + 1)
        qk = minhash_sketch(queries, L, b, vocab, seed=seed + 1)
        idx = SegmentedIndex(L, b, delta_cap=delta_cap, payload_words=Wp)
        ids = idx.insert(sk, payloads=doc_pays)
        assert np.array_equal(ids, np.arange(n_docs))
        plain = idx.topk_batch(qk, k)
        kc = min(cand_mult * k, n_docs)
        rer = idx.topk_batch(qk, kc, rerank="jaccard", q_payloads=q_pays)
        rer_ids = np.asarray(rer.ids)[:, :k]
        rows.append({
            "b": int(b),
            "sketch": round(recall_at_k(np.asarray(plain.ids), truth), 4),
            "reranked": round(recall_at_k(rer_ids, truth), 4),
            "tau_star": int(rer.tau),
        })
    return {"k": int(k), "n_docs": int(n_docs), "L": int(L),
            "vocab": int(vocab), "seed": int(seed), "rows": rows}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny corpus for CI (seconds, same assertions)")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless reranked >= sketch-only "
                         f"and reranked >= {RECALL_FLOOR} for every b")
    ap.add_argument("--out", default=None, help="write JSON report here")
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args(argv)
    kw = dict(n_docs=600, n_queries=20, L=32, delta_cap=256) \
        if args.smoke else {}
    report = evaluate(k=args.k, **kw)
    print(f"# recall@{report['k']} on n={report['n_docs']} docs, "
          f"L={report['L']}, vocab={report['vocab']}")
    print("b,sketch_only,reranked,tau_star")
    for row in report["rows"]:
        print(f"{row['b']},{row['sketch']:.4f},{row['reranked']:.4f},"
              f"{row['tau_star']}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2)
        print(f"# wrote {args.out}")
    if args.check:
        bad = [r for r in report["rows"]
               if r["reranked"] < r["sketch"]
               or r["reranked"] < RECALL_FLOOR]
        if bad:
            print(f"RECALL GATE FAILED (floor {RECALL_FLOOR}): {bad}",
                  file=sys.stderr)
            return 1
        print(f"# recall gate passed: reranked >= sketch-only and >= "
              f"{RECALL_FLOOR} for every b")
    return 0


if __name__ == "__main__":
    sys.exit(main())
