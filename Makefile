PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-fast bench-quick bench dev-deps

test:
	$(PYTHON) -m pytest -q

test-fast:
	$(PYTHON) -m pytest -x -q tests/test_bitvector.py tests/test_bst.py \
		tests/test_hamming_sketch.py tests/test_kernels.py tests/test_topk.py

bench-quick:
	$(PYTHON) -m benchmarks.run --quick

bench:
	$(PYTHON) -m benchmarks.run

dev-deps:
	$(PYTHON) -m pip install -r requirements-dev.txt
